"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

from .epilogue import apply_epilogue


def _unpack_int4(w_packed: jnp.ndarray) -> jnp.ndarray:
    """(K//2, N) nibble-packed int8 -> (K, N) f32 codes (sign-extended)."""
    lo = (((w_packed & 0xF) ^ 8) - 8).astype(jnp.float32)
    hi = ((((w_packed >> 4) & 0xF) ^ 8) - 8).astype(jnp.float32)
    k2, n = w_packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


def pim_matvec_ref(
    x: jnp.ndarray,
    w_codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int = 8,
    bias=None,
    activation: str = "none",
    residual=None,
) -> jnp.ndarray:
    """Oracle for kernels.pim_matvec: unscaled code matmul, then the same
    fused-epilogue order (scale [+ bias] -> activation [+ residual])."""
    w = w_codes.astype(jnp.float32) if bits == 8 else _unpack_int4(w_codes)
    acc = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    bias = None if bias is None else jnp.asarray(bias, jnp.float32).reshape(1, -1)
    res = None if residual is None else residual.astype(jnp.float32)
    return apply_epilogue(acc, scale, bias, res, activation)


def pim_matmul_int8_ref(
    x: jnp.ndarray, w_codes: jnp.ndarray, scale: jnp.ndarray
) -> jnp.ndarray:
    """f32(M,K) @ dequant(int8 (K,N), scale (1,N)) -> f32 (M,N)."""
    w = w_codes.astype(jnp.float32) * scale
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def pim_matmul_int4_ref(
    x: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray
) -> jnp.ndarray:
    """Nibble-packed variant: w_packed (K//2, N) int8 (low nibble = even K)."""
    lo = (((w_packed & 0xF) ^ 8) - 8).astype(jnp.int8)
    hi = ((((w_packed >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)
    k2, n = w_packed.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n).astype(jnp.float32) * scale
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def bitplane_matmul_ref(
    x: jnp.ndarray, planes: jnp.ndarray, scale: jnp.ndarray
) -> jnp.ndarray:
    """Bit-plane-decomposed matmul (the PIM-semantic form).

    planes: (B, K, N) in {0,1}; two's complement, LSB-first.
    out = sum_b weight_b * (x @ plane_b) * scale — one 'bit-serial step' per
    plane, mirroring how a PiCaSO PE consumes the striped operand.
    """
    bits = planes.shape[0]
    weights = 2.0 ** jnp.arange(bits)
    weights = weights.at[bits - 1].multiply(-1.0)
    acc = jnp.zeros((x.shape[0], planes.shape[2]), jnp.float32)
    for b in range(bits):
        acc = acc + weights[b] * jnp.dot(
            x.astype(jnp.float32),
            planes[b].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    return acc * scale


def fold_reduce_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Sum along the last axis (the OpMux fold tree computes exactly this).

    Uses the same halve-and-add association order as the kernel so float
    results are bit-identical.
    """
    q = x.shape[-1]
    assert q & (q - 1) == 0, "q must be a power of two"
    while x.shape[-1] > 1:
        h = x.shape[-1] // 2
        x = x[..., :h] + x[..., h:]
    return x[..., 0]
