"""Bit-plane-decomposed matmul — the PIM-semantic Pallas kernel.

This is the *faithful* spatial translation of PiCaSO's bit-serial MAC: the
quantized weight matrix is stored as B one-bit planes (LSB first, two's
complement), and the kernel consumes one plane per inner step — each step is
the TPU analogue of one bit-serial ALU pass over the striped operand, with
the shift-weights 2^b applied at accumulate time (the Booth-style
shift-accumulate).  ``pim_matmul`` is the throughput-oriented packed variant;
this kernel exists to keep the paper's execution semantics runnable and
testable end-to-end.

Grid: (M/bm, N/bn, K/bk); the B planes of each (bk, bn) weight tile arrive
as one (B, bk, bn) block.  Non-multiple shapes are zero-padded to tile; the
final K step applies the same fused epilogue as ``pim_matmul``
(scale [+ bias] -> activation [+ residual], see kernels.epilogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .epilogue import (
    apply_epilogue,
    build_epilogue_inputs,
    normalize_bias,
    pad_axis,
    round_up,
    unpack_epilogue_refs,
)


def _bitplane_kernel(x_ref, p_ref, s_ref, *rest, n_k: int, bits: int,
                     activation: str, has_bias: bool, has_residual: bool):
    o_ref, b_ref, r_ref = unpack_epilogue_refs(rest, has_bias, has_residual)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(o_ref)
    for b in range(bits):  # one 'bit-serial step' per plane
        weight = float(2**b) if b < bits - 1 else float(-(2 ** b))
        plane = p_ref[b].astype(jnp.float32)
        acc += weight * jnp.dot(x, plane, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = apply_epilogue(
            o_ref[...], s_ref[...],
            b_ref[...] if has_bias else None,
            r_ref[...] if has_residual else None,
            activation,
        )


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def bitplane_matmul(
    x: jnp.ndarray,
    planes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    activation: str = "none",
    residual: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M,K) @ bitplanes (B,K,N) * scale (1,N) -> (M,N) f32, epilogue fused."""
    m, k_dim = x.shape
    bits, k_w, n = planes.shape
    assert k_w == k_dim
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k_dim)
    m_pad, n_pad, k_pad = round_up(m, bm), round_up(n, bn), round_up(k_dim, bk)
    n_k = k_pad // bk

    bias = normalize_bias(bias, n)
    x = pad_axis(pad_axis(x, 1, k_pad), 0, m_pad)
    planes = pad_axis(pad_axis(planes, 1, k_pad), 2, n_pad)
    scale = pad_axis(scale, 1, n_pad)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bits, bk, bn), lambda i, j, k: (0, k, j)),
        pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
    ]
    operands = [x, planes, scale]
    ep_specs, ep_ops = build_epilogue_inputs(
        bias, residual, m=m, n=n, m_pad=m_pad, n_pad=n_pad, bm=bm, bn=bn,
        row_map=lambda i, j, k: (0, j), tile_map=lambda i, j, k: (i, j))
    in_specs += ep_specs
    operands += ep_ops

    out = pl.pallas_call(
        functools.partial(
            _bitplane_kernel, n_k=n_k, bits=bits, activation=activation,
            has_bias=bias is not None, has_residual=residual is not None,
        ),
        grid=(m_pad // bm, n_pad // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(*operands)
    if m_pad != m or n_pad != n:
        out = out[:m, :n]
    return out
