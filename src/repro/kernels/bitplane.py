"""Bit-plane-decomposed matmul — the PIM-semantic Pallas kernel.

This is the *faithful* spatial translation of PiCaSO's bit-serial MAC: the
quantized weight matrix is stored as B one-bit planes (LSB first, two's
complement), and the kernel consumes one plane per inner step — each step is
the TPU analogue of one bit-serial ALU pass over the striped operand, with
the shift-weights 2^b applied at accumulate time (the Booth-style
shift-accumulate).  ``pim_matmul`` is the throughput-oriented packed variant;
this kernel exists to keep the paper's execution semantics runnable and
testable end-to-end.

Grid: (M/bm, N/bn, K/bk); the B planes of each (bk, bn) weight tile arrive
as one (B, bk, bn) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitplane_kernel(x_ref, p_ref, s_ref, o_ref, *, n_k: int, bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(o_ref)
    for b in range(bits):  # one 'bit-serial step' per plane
        weight = float(2**b) if b < bits - 1 else float(-(2 ** b))
        plane = p_ref[b].astype(jnp.float32)
        acc += weight * jnp.dot(x, plane, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] *= s_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def bitplane_matmul(
    x: jnp.ndarray,
    planes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M,K) @ bitplanes (B,K,N) * scale (1,N) -> (M,N) f32."""
    m, k_dim = x.shape
    bits, k_w, n = planes.shape
    assert k_w == k_dim
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k_dim)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0
    n_k = k_dim // bk

    return pl.pallas_call(
        functools.partial(_bitplane_kernel, n_k=n_k, bits=bits),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bits, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, planes, scale)
