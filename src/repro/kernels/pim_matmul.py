"""Dequant-fused quantized matmul — the TPU-native PiCaSO adaptation.

PIM thesis: compute sits at the memory boundary, operands are stored at
reduced precision, so throughput is limited only by memory bandwidth.  On TPU
the analogous structure is: weights live in HBM as INT8 codes or INT4 nibble
pairs; each grid step DMAs a *packed* tile into VMEM, expands it to f32 right
next to the MXU, and accumulates into the resident output tile.  HBM traffic
for the weights drops 4x/8x vs f32 (2x/4x vs bf16), moving memory-bound
layers (decode-time matvecs — the paper's MLP/RNN regime, §I) toward the
compute roofline.

Tiling: grid (M/bm, N/bn, K/bk), K innermost; the output BlockSpec ignores k,
so the f32 accumulator tile stays resident in VMEM across the K sweep (zero
spill) — exactly like PiCaSO keeping partial sums in the PE register file
during a row MAC.  MXU alignment: bm/bn/bk multiples of 128 for full-size
inputs (smaller shapes shrink the tile); shapes that are not multiples of
the chosen blocks are zero-padded to tile and the output sliced back.

Epilogue: the final K step applies scale × acc [+ bias] → activation
[+ residual] while the tile is still in VMEM (see kernels.epilogue), so the
per-output ops never round-trip through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .epilogue import (
    apply_epilogue,
    build_epilogue_inputs,
    normalize_bias,
    pad_axis,
    quant_accumulate,
    round_up,
    unpack_epilogue_refs,
)


def _mm_kernel(x_ref, w_ref, s_ref, *rest, n_k: int, bits: int,
               activation: str, has_bias: bool, has_residual: bool):
    o_ref, b_ref, r_ref = unpack_epilogue_refs(rest, has_bias, has_residual)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the weight tile at the VMEM boundary (the 'BRAM port').
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += quant_accumulate(x, w_ref[...], bits)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = apply_epilogue(
            o_ref[...], s_ref[...],
            b_ref[...] if has_bias else None,
            r_ref[...] if has_residual else None,
            activation,
        )


def _pick(block: int, dim: int) -> int:
    return min(block, dim)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "activation", "bm", "bn", "bk", "interpret"),
)
def pim_matmul(
    x: jnp.ndarray,
    w_codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int = 8,
    bias: jnp.ndarray | None = None,
    activation: str = "none",
    residual: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M,K) f32/bf16 @ quantized w -> (M,N) f32, epilogue fused.

    bits=8: ``w_codes`` is (K, N) int8.  bits=4: ``w_codes`` is the
    nibble-packed (K//2, N) int8 from ``quant.pack_int4``.
    ``scale``: (1, N) f32 per-output-channel scale.  ``bias``: (N,) or
    (1, N); ``residual``: (M, N); ``activation``: none|relu|silu|gelu.
    """
    m, k_dim = x.shape
    if bits == 8:
        k_w, n = w_codes.shape
        assert k_w == k_dim, (k_w, k_dim)
    elif bits == 4:
        k_w, n = w_codes.shape
        assert 2 * k_w == k_dim, (k_w, k_dim)
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")

    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k_dim)
    if bits == 4 and bk % 2:
        bk += 1  # keep nibble pairs whole
    m_pad, n_pad, k_pad = round_up(m, bm), round_up(n, bn), round_up(k_dim, bk)
    n_k = k_pad // bk
    grid = (m_pad // bm, n_pad // bn, n_k)

    bias = normalize_bias(bias, n)
    x = pad_axis(pad_axis(x, 1, k_pad), 0, m_pad)
    scale = pad_axis(scale, 1, n_pad)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    if bits == 8:
        w_codes = pad_axis(pad_axis(w_codes, 0, k_pad), 1, n_pad)
        w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    else:
        w_codes = pad_axis(pad_axis(w_codes, 0, k_pad // 2), 1, n_pad)
        w_spec = pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j))
    s_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))

    in_specs = [x_spec, w_spec, s_spec]
    operands = [x, w_codes, scale]
    ep_specs, ep_ops = build_epilogue_inputs(
        bias, residual, m=m, n=n, m_pad=m_pad, n_pad=n_pad, bm=bm, bn=bn,
        row_map=lambda i, j, k: (0, j), tile_map=lambda i, j, k: (i, j))
    in_specs += ep_specs
    operands += ep_ops

    out = pl.pallas_call(
        functools.partial(
            _mm_kernel, n_k=n_k, bits=bits, activation=activation,
            has_bias=bias is not None, has_residual=residual is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(*operands)
    if m_pad != m or n_pad != n:
        out = out[:m, :n]
    return out
