"""Dequant-fused quantized matmul — the TPU-native PiCaSO adaptation.

PIM thesis: compute sits at the memory boundary, operands are stored at
reduced precision, so throughput is limited only by memory bandwidth.  On TPU
the analogous structure is: weights live in HBM as INT8 codes or INT4 nibble
pairs; each grid step DMAs a *packed* tile into VMEM, expands it to f32 right
next to the MXU, and accumulates into the resident output tile.  HBM traffic
for the weights drops 4x/8x vs f32 (2x/4x vs bf16), moving memory-bound
layers (decode-time matvecs — the paper's MLP/RNN regime, §I) toward the
compute roofline.

Tiling: grid (M/bm, N/bn, K/bk), K innermost; the output BlockSpec ignores k,
so the f32 accumulator tile stays resident in VMEM across the K sweep (zero
spill) — exactly like PiCaSO keeping partial sums in the PE register file
during a row MAC.  MXU alignment: bm/bn/bk multiples of 128 for full-size
inputs (smaller shapes shrink the tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_int8_kernel(x_ref, w_ref, s_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the weight tile at the VMEM boundary (the 'BRAM port').
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] *= s_ref[...]


def _mm_int4_kernel(x_ref, w_ref, s_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = w_ref[...]  # (bk//2, bn) int8: two K rows per byte
    lo = (((packed & 0xF) ^ 8) - 8).astype(jnp.float32)
    hi = ((((packed >> 4) & 0xF) ^ 8) - 8).astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    # Even K rows hit the low nibbles, odd K rows the high nibbles.
    o_ref[...] += jnp.dot(x[:, 0::2], lo, preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(x[:, 1::2], hi, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] *= s_ref[...]


def _pick(block: int, dim: int) -> int:
    return min(block, dim)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret"))
def pim_matmul(
    x: jnp.ndarray,
    w_codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int = 8,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M,K) f32/bf16 @ quantized w -> (M,N) f32.

    bits=8: ``w_codes`` is (K, N) int8.  bits=4: ``w_codes`` is the
    nibble-packed (K//2, N) int8 from ``quant.pack_int4``.
    ``scale``: (1, N) f32 per-output-channel scale.
    """
    m, k_dim = x.shape
    if bits == 8:
        k_w, n = w_codes.shape
        assert k_w == k_dim, (k_w, k_dim)
    elif bits == 4:
        k_w, n = w_codes.shape
        assert 2 * k_w == k_dim, (k_w, k_dim)
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")

    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k_dim)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, (m, n, k_dim, bm, bn, bk)
    if bits == 4:
        assert bk % 2 == 0
    n_k = k_dim // bk
    grid = (m // bm, n // bn, n_k)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    if bits == 8:
        w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
        kernel = functools.partial(_mm_int8_kernel, n_k=n_k)
    else:
        w_spec = pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j))
        kernel = functools.partial(_mm_int4_kernel, n_k=n_k)
    s_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_codes, scale)
