"""OpMux-style folding reduction as a Pallas kernel (paper Fig 2 / §III-C).

Reduces the last axis of ``(rows, q)`` to ``(rows,)`` by log2(q) halve-and-add
steps inside VMEM — the spatial analogue of the A-FOLD-1..4 serial passes: at
each level the 'second half' of the tile is the Y operand of an element-wise
add with the first half, no copies through HBM ('bitlines').

Used for partial-sum trees (MoE top-k combine, attention denominator folds)
and as the in-tile half of the hierarchical reduction whose cross-device half
is the binary-hopping collective schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fold_kernel(x_ref, o_ref, *, q: int):
    x = x_ref[...].astype(jnp.float32)  # (br, q)
    h = q
    while h > 1:
        h //= 2
        x = x[:, :h] + x[:, h:2 * h]  # A-FOLD level: Y = second half of A
    o_ref[...] = x  # (br, 1)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fold_reduce(
    x: jnp.ndarray, *, br: int = 256, interpret: bool = False
) -> jnp.ndarray:
    """Fold-sum the last axis of ``x`` (rows, q) -> (rows,). q: power of two."""
    rows, q = x.shape
    assert q & (q - 1) == 0, f"q={q} must be a power of two"
    br = min(br, rows)
    assert rows % br == 0, (rows, br)

    out = pl.pallas_call(
        functools.partial(_fold_kernel, q=q),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, q), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, 0]
