"""Decode-shaped PIM matvec — epilogue-fused quantized GEMV (Pallas).

The decode regime the paper targets (§I: MLP/RNN inference dominated by
weight traffic) has M = batch ≤ 8 rows of activations against a (K, N)
quantized weight: the matmul is pure weight streaming, and every extra HBM
round-trip (dequant materialisation, bias add, activation, residual) costs
as much as the matmul itself.  This kernel keeps the whole output tile
resident in VMEM for the full K sweep and runs the epilogue
(scale × acc + bias → activation → + residual) in the flush step, so the
only HBM traffic is: packed codes in, final activations out — the PiCaSO
structure (compute at the BRAM port) applied to serving.

Grid: (N/bn, K/bk), K innermost.  M is padded to 8 (the f32 sublane tile);
K and N are padded to the block sizes, so non-multiple shapes work (zero
codes/activations contribute zero).  bits=8 streams int8 codes; bits=4
streams nibble-packed pairs (two K rows per byte) and unpacks next to the
MXU — 8x less weight HBM traffic than f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .epilogue import (
    apply_epilogue,
    build_epilogue_inputs,
    normalize_bias,
    pad_axis,
    quant_accumulate,
    round_up,
    unpack_epilogue_refs,
)

MAX_M = 8  # decode-shaped: one f32 sublane tile of activation rows


def _mv_kernel(x_ref, w_ref, s_ref, *rest, n_k: int, bits: int,
               activation: str, has_bias: bool, has_residual: bool):
    o_ref, b_ref, r_ref = unpack_epilogue_refs(rest, has_bias, has_residual)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (8, bk)
    o_ref[...] += quant_accumulate(x, w_ref[...], bits)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = apply_epilogue(
            o_ref[...], s_ref[...],
            b_ref[...] if has_bias else None,
            r_ref[...] if has_residual else None,
            activation,
        )


@functools.partial(
    jax.jit, static_argnames=("bits", "activation", "bn", "bk", "interpret")
)
def pim_matvec(
    x: jnp.ndarray,
    w_codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int = 8,
    bias: jnp.ndarray | None = None,
    activation: str = "none",
    residual: jnp.ndarray | None = None,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M≤8, K) @ quantized w -> (M, N) f32, epilogue fused.

    bits=8: ``w_codes`` is (K, N) int8.  bits=4: ``w_codes`` is the
    nibble-packed (K//2, N) int8 from ``quant.pack_int4``.
    ``scale``: (1, N) f32 per-output-channel scale.  ``bias``: (N,) or
    (1, N); ``residual``: (M, N); ``activation``: none|relu|silu|gelu.
    Shapes that are not block multiples are zero-padded to tile.
    """
    m, k_dim = x.shape
    if m > MAX_M:
        raise ValueError(f"pim_matvec is decode-shaped (M <= {MAX_M}); "
                         f"got M={m} — use pim_matmul")
    if bits == 8:
        k_w, n = w_codes.shape
        assert k_w == k_dim, (k_w, k_dim)
    elif bits == 4:
        k_w, n = w_codes.shape
        assert 2 * k_w == k_dim, (k_w, k_dim)
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")

    bn = min(bn, n)
    bk = min(bk, k_dim)
    if bits == 4 and bk % 2:
        bk += 1  # keep nibble pairs whole
    n_pad, k_pad = round_up(n, bn), round_up(k_dim, bk)

    bias = normalize_bias(bias, n)
    x = pad_axis(pad_axis(x, 1, k_pad), 0, MAX_M)
    scale = pad_axis(scale, 1, n_pad)
    if bits == 8:
        w_codes = pad_axis(pad_axis(w_codes, 0, k_pad), 1, n_pad)
        w_spec = pl.BlockSpec((bk, bn), lambda j, k: (k, j))
    else:
        w_codes = pad_axis(pad_axis(w_codes, 0, k_pad // 2), 1, n_pad)
        w_spec = pl.BlockSpec((bk // 2, bn), lambda j, k: (k, j))

    n_k = k_pad // bk
    grid = (n_pad // bn, n_k)

    in_specs = [
        pl.BlockSpec((MAX_M, bk), lambda j, k: (0, k)),
        w_spec,
        pl.BlockSpec((1, bn), lambda j, k: (0, j)),
    ]
    operands = [x, w_codes, scale]
    ep_specs, ep_ops = build_epilogue_inputs(
        bias, residual, m=m, n=n, m_pad=MAX_M, n_pad=n_pad, bm=MAX_M, bn=bn,
        row_map=lambda j, k: (0, j), tile_map=lambda j, k: (0, j))
    in_specs += ep_specs
    operands += ep_ops

    out = pl.pallas_call(
        functools.partial(
            _mv_kernel, n_k=n_k, bits=bits, activation=activation,
            has_bias=bias is not None, has_residual=residual is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((MAX_M, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((MAX_M, n_pad), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
