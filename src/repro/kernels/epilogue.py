"""Shared epilogue + pad-to-tile helpers for the PIM matmul kernels.

The epilogue is the set of per-output ops (channel scale, bias, activation,
residual add) that a naive lowering runs as separate XLA ops AFTER the
matmul — each one a full (M, N) round-trip through HBM.  Fusing them into
the kernel's flush step keeps the tile in VMEM until the final value is
written once: the PIM discipline (compute at the memory boundary) applied to
the epilogue, not just the dequant.

``apply_epilogue`` is pure jnp so the same code runs inside a Pallas kernel
body (on VMEM tiles) and in the pure-jnp oracles (kernels.ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = {
    "none": lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0.0),
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}


def apply_epilogue(acc, scale, bias, residual, activation: str):
    """acc * scale [+ bias] -> activation -> [+ residual], all in f32."""
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"one of {sorted(ACTIVATIONS)}")
    y = acc * scale
    if bias is not None:
        y = y + bias
    y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual
    return y


def quant_accumulate(x, w_tile, bits: int):
    """One K-step contribution: x (bm, bk) f32 @ quantized weight tile.

    bits=8: ``w_tile`` is (bk, bn) int8, dequantized at the VMEM boundary.
    bits=4: ``w_tile`` is (bk//2, bn) nibble-packed int8 — even K rows hit
    the low nibbles, odd K rows the high nibbles.  Shared by pim_matmul and
    pim_matvec so the dequant semantics can never drift between them.
    """
    if bits == 8:
        return jnp.dot(x, w_tile.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    lo = (((w_tile & 0xF) ^ 8) - 8).astype(jnp.float32)
    hi = ((((w_tile >> 4) & 0xF) ^ 8) - 8).astype(jnp.float32)
    return (jnp.dot(x[:, 0::2], lo, preferred_element_type=jnp.float32)
            + jnp.dot(x[:, 1::2], hi, preferred_element_type=jnp.float32))


def unpack_epilogue_refs(rest, has_bias: bool, has_residual: bool):
    """(o_ref, b_ref, r_ref) from a kernel's trailing variadic refs
    (ordering: [bias?], [residual?], out)."""
    o_ref = rest[-1]
    b_ref = rest[0] if has_bias else None
    r_ref = rest[1 if has_bias else 0] if has_residual else None
    return o_ref, b_ref, r_ref


def round_up(dim: int, mult: int) -> int:
    return -(-dim // mult) * mult


def pad_axis(a, axis: int, target: int):
    """Zero-pad ``axis`` of ``a`` up to length ``target`` (no-op if equal)."""
    cur = a.shape[axis]
    if cur == target:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(a, widths)


def normalize_bias(bias, n: int):
    """Accept (N,) or (1, N) bias; return (1, N) f32 or None."""
    if bias is None:
        return None
    b = jnp.asarray(bias, jnp.float32).reshape(1, -1)
    assert b.shape[1] == n, (b.shape, n)
    return b


def build_epilogue_inputs(bias, residual, *, m: int, n: int, m_pad: int,
                          n_pad: int, bm: int, bn: int, row_map, tile_map):
    """BlockSpecs + padded operands for the optional epilogue inputs.

    Shared by pim_matmul / pim_matvec / bitplane_matmul so the bias and
    residual padding/dtype handling can never drift between kernels.
    ``row_map``/``tile_map`` are the grid index maps for a (1, bn) row
    block and a (bm, bn) tile block respectively (grid arity differs per
    kernel).  ``bias`` must already be normalized via ``normalize_bias``.
    """
    specs, operands = [], []
    if bias is not None:
        specs.append(pl.BlockSpec((1, bn), row_map))
        operands.append(pad_axis(bias, 1, n_pad))
    if residual is not None:
        assert residual.shape == (m, n), (residual.shape, m, n)
        specs.append(pl.BlockSpec((bm, bn), tile_map))
        operands.append(
            pad_axis(pad_axis(residual.astype(jnp.float32), 1, n_pad), 0, m_pad))
    return specs, operands
