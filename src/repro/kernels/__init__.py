"""PIM compute kernels (Pallas, TPU target; interpret-mode validated on CPU).

  pim_matmul   — dequant-fused INT4/INT8 weight matmul (the PIM adaptation)
  pim_matvec   — decode-shaped (M<=8) variant with the fused epilogue
  bitplane     — bit-plane-decomposed matmul (PIM-semantic faithful form)
  fold_reduce  — OpMux-style log-step folding reduction
  epilogue     — shared epilogue (scale/bias/activation/residual) + padding
  ops          — jit'd public wrappers;  ref — pure-jnp oracles
"""
from .ops import (
    bitplane_matmul,
    fold_reduce,
    fold_sum,
    pim_dense,
    pim_dense_bitplane,
    pim_matmul,
    pim_matvec,
    pim_matvec_dense,
    quantize_for_pim,
)
from . import ref

__all__ = [
    "pim_matmul", "pim_matvec", "bitplane_matmul", "fold_reduce", "ref",
    "quantize_for_pim", "pim_dense", "pim_matvec_dense",
    "pim_dense_bitplane", "fold_sum",
]
