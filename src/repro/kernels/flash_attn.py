"""Flash attention (Pallas, TPU target) — VMEM-resident online softmax.

The dry-run roofline shows train cells dominated by S^2 score traffic: the
XLA attention path materialises (B,H,S,S) f32 scores in HBM (fwd + bwd).
This kernel is the canonical fix: the grid walks (batch*heads, q-blocks),
each program streams KV blocks through VMEM with a running (max, denom, acc)
triple, so nothing S^2-sized ever reaches HBM — the same
transfer-compute-overlap insight as PiCaSO's binary-hopping network, applied
at the VMEM boundary.

Validated against models.attention._direct_attention in interpret mode
(tests/test_flash_attn.py); the roofline credits it via the S^2-traffic
adjustment in launch.roofline.flash_adjusted (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sk: int, bkv: int,
                  causal: bool, bq: int, scale: float):
    qi = pl.program_id(1)  # q-block index
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)

    n_kv = sk // bkv

    def body(j, carry):
        m, l, acc = carry
        # Size-1 dslice instead of a bare int index: jax 0.4.37's interpret-
        # mode discharge rule rejects scalar int indexers inside pl.load.
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bkv, bkv), slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bkv, bkv), slice(None)))[0]
        s = q @ k.astype(jnp.float32).T  # (bq, bkv)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bkv", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Sk, D)
    v: jnp.ndarray,  # (BH, Sk, D)
    *,
    causal: bool = True,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention over flattened (batch*heads) leading dim."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    bq = min(bq, sq)
    bkv = min(bkv, sk)
    assert sq % bq == 0 and sk % bkv == 0, (sq, bq, sk, bkv)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, sk=sk, bkv=bkv, causal=causal, bq=bq, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, sk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """Pure-jnp oracle (naive softmax attention)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
